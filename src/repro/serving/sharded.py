"""Serving-side scene staging + the legacy sharded-dispatch shim.

The actual device-sharded dispatch (cameras over 'data', gaussians over
'model') lives in the engine handle now (``repro.engine``, DESIGN.md §11):
a ``Renderer`` commits the scene layout once — and, with it, the
projected-feature gather strategy (DESIGN.md §12: the owner-masked psum
form when the 'model' axis is physical, so per-camera features stay at N/D
per device) — and every ``render_batch`` reuses both. This module keeps the
two serving-side pieces the handle builds on, plus the deprecated
free-function entry:

  * ``pad_camera_batch`` — the array-level ragged-batch padding built on the
    ``pad_indices_to`` policy (mask-correct: the padded tail replicates the
    last camera and is sliced off after the dispatch, DESIGN.md §9);
  * the scene-LAYOUT cache (``shard_scene_cached``): the host-staged
    padded/sharded layout per (scene identity, D), registered with
    ``core.pipeline.register_render_cache`` so ``render_cache_clear()`` /
    ``render_cache_info()`` cover it and the server's cache-hit stats stay
    truthful; handles hold layouts through the refcounted
    ``acquire_scene_layout``/``release_scene_layout`` pair (a layout frees
    when its LAST handle closes — never under another open handle), and
    ``evict_scene_layouts`` drops a scene's unreferenced layouts;
  * ``render_batch_sharded`` — a DeprecationWarning shim delegating to the
    module-default handle, bitwise-identical to the handle path by
    construction.
"""
from __future__ import annotations

import dataclasses
import warnings
import weakref
from typing import Optional, Sequence, Union

import numpy as np
from jax.sharding import Mesh

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.pipeline import (
    CameraBatch,
    RenderConfig,
    RenderResult,
    register_render_cache,
)
from repro.serving.bucketing import pad_indices_to
from repro.sharding.scene import ShardedScene, shard_scene_host


def pad_camera_batch(batch: CameraBatch, target: int) -> CameraBatch:
    """Pad the batch axis up to ``target`` lanes by replicating the last
    camera (the ``pad_indices_to`` policy); identity when already there."""
    n = len(batch)
    idx = pad_indices_to(n, target)
    if len(idx) == n:
        return batch
    take = np.asarray(idx)
    return dataclasses.replace(
        batch,
        R=batch.R[take],
        t=batch.t[take],
        fx=batch.fx[take],
        fy=batch.fy[take],
        cx=batch.cx[take],
        cy=batch.cy[take],
    )


# ---------------------------------------------------------------------------
# Scene-layout cache (registered with the engine's cache registry)
# ---------------------------------------------------------------------------

_LAYOUT_CACHE_MAX = 16
_layout_cache: dict = {}           # (id(scene), D) -> ShardedScene
_layout_refs: dict = {}            # (id(scene), D) -> open-handle refcount
_layout_stats = {"hits": 0, "misses": 0}


def _layout_info() -> dict:
    return {
        "hits": _layout_stats["hits"],
        "misses": _layout_stats["misses"],
        "currsize": len(_layout_cache),
        "maxsize": _LAYOUT_CACHE_MAX,
    }


def _layout_clear() -> None:
    _layout_cache.clear()
    _layout_stats["hits"] = 0
    _layout_stats["misses"] = 0


register_render_cache("scene_layout", info=_layout_info, clear=_layout_clear)


def shard_scene_cached(scene: GaussianScene, num_shards: int) -> ShardedScene:
    """Host-side ``shard_scene_host`` memoized per (scene identity, D).

    The padded/sharded layout of a served scene is rebuilt at most once per
    dispatch stream and held as HOST arrays (numpy): it never pins device
    memory — ``device_put`` with ``scene_shard_pspec`` transfers each shard
    to its own device, with no full-scene allocation on any single device.
    Entries are evicted when the source scene is garbage collected (weakref
    finalizer — id() keys alone could alias a recycled object) or by FIFO
    once the cache holds ``_LAYOUT_CACHE_MAX`` layouts. Covered by
    ``render_cache_clear``/``render_cache_info`` ("scene_layout").
    """
    key = (id(scene), int(num_shards))
    hit = _layout_cache.get(key)
    if hit is not None:
        _layout_stats["hits"] += 1
        return hit
    _layout_stats["misses"] += 1
    out = shard_scene_host(scene, num_shards)
    if len(_layout_cache) >= _LAYOUT_CACHE_MAX:
        # Capacity eviction skips REFERENCED layouts (an open handle's
        # backing store must not vanish under it); the cache may exceed
        # its nominal max while that many handles are open — bounded by
        # the open-handle count, not unbounded growth.
        for k in list(_layout_cache):
            if len(_layout_cache) < _LAYOUT_CACHE_MAX:
                break
            if _layout_refs.get(k, 0) <= 0:
                _layout_cache.pop(k)
    _layout_cache[key] = out
    weakref.finalize(scene, _drop_layout_key, key)
    return out


def _drop_layout_key(key) -> None:
    """Scene-GC finalizer: with the source scene gone no handle can hold a
    layout reference legitimately — drop both maps (id() may be recycled)."""
    _layout_cache.pop(key, None)
    _layout_refs.pop(key, None)


def acquire_scene_layout(scene: GaussianScene, num_shards: int):
    """``shard_scene_cached`` plus a reference: the layout stays cached (and
    exempt from capacity eviction) until every acquirer releases.

    The shared-eviction fix: ``Renderer.close()`` used to call
    :func:`evict_scene_layouts` unconditionally, nuking layouts still
    referenced by OTHER open handles committed on the same scene; handles
    now acquire here and release exactly their own ``(scene, D)`` entry.
    """
    out = shard_scene_cached(scene, num_shards)
    key = (id(scene), int(num_shards))
    _layout_refs[key] = _layout_refs.get(key, 0) + 1
    return out


def release_scene_layout(scene: GaussianScene, num_shards: int) -> bool:
    """Drop one reference on ``(scene, num_shards)``; the LAST release
    evicts the cached layout. True when the layout was actually dropped."""
    key = (id(scene), int(num_shards))
    remaining = _layout_refs.get(key, 0) - 1
    if remaining > 0:
        _layout_refs[key] = remaining
        return False
    _layout_refs.pop(key, None)
    return _layout_cache.pop(key, None) is not None


def evict_scene_layouts(scene: GaussianScene) -> int:
    """Drop every UNREFERENCED cached layout of ``scene``, at any shard
    count (explicit cache hygiene for code that staged layouts outside a
    handle). Layouts still referenced by open handles survive — use
    :func:`release_scene_layout` for those. Returns the eviction count."""
    sid = id(scene)
    keys = [
        k for k in _layout_cache
        if k[0] == sid and _layout_refs.get(k, 0) <= 0
    ]
    for k in keys:
        _layout_cache.pop(k, None)
    return len(keys)


# ---------------------------------------------------------------------------
# Sharded dispatch
# ---------------------------------------------------------------------------


def render_batch_sharded(
    scene: Union[GaussianScene, ShardedScene],
    cams: Union[CameraBatch, Sequence[Camera]],
    cfg: RenderConfig,
    background=None,
    *,
    mesh: Optional[Mesh] = None,
    pad_to: Optional[int] = None,
    scene_shards: Optional[int] = None,
) -> RenderResult:
    """Deprecated: ``repro.engine.open(scene, cfg, mesh=mesh).render_batch``.

    Delegates to the module-default handle for ``(scene, cfg, mesh)``
    (``repro.engine.default_renderer``), preserving the legacy semantics:
    ``scene_shards`` (default: ``cfg.scene_shards``, or the layout of an
    already-sharded scene) selects the gaussian-axis shard count D;
    ``mesh=None`` builds the matching render mesh over all local devices
    with the ``render_mesh_shards`` logical fallback; the batch is padded to
    ``max(B, pad_to)`` rounded up to the mesh's DATA extent and exactly B
    images/stats come back. The handle is what now owns the committed scene
    placement and the compiled-renderer cache (DESIGN.md §11).
    """
    warnings.warn(
        "render_batch_sharded() is deprecated; open a handle with "
        "repro.engine.open(scene, cfg, mesh=...) and call "
        ".render_batch(cams, pad_to=...)",
        DeprecationWarning,
        stacklevel=2,
    )
    if scene_shards is None:
        scene_shards = (
            scene.num_shards
            if isinstance(scene, ShardedScene)
            else cfg.scene_shards
        )
    if cfg.scene_shards != scene_shards:
        cfg = dataclasses.replace(cfg, scene_shards=scene_shards)

    from repro import engine

    handle = engine.default_renderer(scene, cfg, mesh=mesh)
    return handle.render_batch(cams, pad_to=pad_to, background=background)
