"""Bounded render-request queue with backpressure (DESIGN.md §9).

Pure Python by design: no jax import, so the admission layer (and its tests)
runs anywhere — the first jax touch in the serving stack is the dispatch in
serving/sharded.py. Thread-safe and async-friendly: ``put``/``get_batch``
block with timeouts (a thread-pool bridge works under asyncio), and the
non-blocking ``try_put``/``drain`` variants poll cleanly from an event loop.

A ``RenderRequest`` carries everything the bucketer needs to key the static
jit signature (scene id + render config + camera geometry) plus the dynamic
camera itself. The camera is duck-typed — anything exposing
width/height/znear/zfar (and, by dispatch time, R/t/fx/fy/cx/cy) works, so
pure-Python tests can use stubs.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs.metrics import get_registry  # pure Python — no jax


class QueueFull(Exception):
    """Raised by ``put_nowait`` when the queue is at depth — the caller must
    shed load or retry later (backpressure is explicit, never silent)."""


class QueueClosed(Exception):
    """Raised on ``put`` after ``close()`` — late arrivals are rejected."""


@dataclasses.dataclass(frozen=True)
class RenderRequest:
    """One camera to render against one scene under one config.

    ``cfg`` is treated as an opaque hashable (a ``RenderConfig`` in
    production); ``deadline`` is an absolute time on the server clock or
    None for best-effort; ``enqueue_time`` is stamped by the queue.
    """

    request_id: int
    scene_id: str
    camera: Any
    cfg: Any
    deadline: Optional[float] = None
    enqueue_time: Optional[float] = None
    # Stream affinity (DESIGN.md §15): frames of one interactive camera
    # stream set a shared stream_id so they bucket together and route to
    # that stream's session (its frontend cache + speculation worker)
    # instead of the stateless batch path. None = stateless request.
    stream_id: Optional[str] = None
    # Lifecycle stamps (DESIGN.md §14): monotonic clock readings keyed
    # enqueue/batch_form/dispatch/device_done/resolve, written by the queue,
    # scheduler, and server as the request moves through them. A mutable
    # dict on a frozen dataclass on purpose — the dict OBJECT survives the
    # ``dataclasses.replace`` copies this request goes through, so every
    # phase writes into one shared map; compare=False keeps it out of the
    # generated ``__eq__``.
    stamps: Dict[str, float] = dataclasses.field(
        default_factory=dict, compare=False, repr=False
    )

    def signature(self) -> tuple:
        """The bucketing key: everything the compiled executable specializes
        on, plus the scene id (one ``render_batch`` call serves one scene).
        Mirrors ``core.pipeline.batch_signature`` with scene identity added.
        Stream frames additionally key on their ``stream_id`` — that is the
        whole affinity mechanism: a stream's frames can only ever share a
        bucket with each other, and the FIFO queue + in-order bucket appends
        preserve per-stream frame order through to the session dispatch.
        """
        cam = self.camera
        sig = (self.scene_id, self.cfg, cam.width, cam.height,
               cam.znear, cam.zfar)
        if self.stream_id is not None:
            sig += ("stream", self.stream_id)
        return sig


class RequestQueue:
    """FIFO of ``RenderRequest`` with bounded depth.

    Depth bounds memory and converts overload into backpressure at the edge
    instead of unbounded latency in the scheduler. ``accepted`` counts
    admitted requests; ``rejected`` counts failed put ATTEMPTS (a caller that
    retries after backpressure adds one per failed try — dropped-request
    accounting lives in ``ServingStats.rejected``, not here).
    """

    def __init__(self, maxsize: int = 64, clock=None):
        if maxsize <= 0:
            raise ValueError(f"queue maxsize must be positive, got {maxsize}")
        self.maxsize = maxsize
        self._clock = clock or time.monotonic
        self._items: List[RenderRequest] = []
        self._cond = threading.Condition()
        self._closed = False
        self.accepted = 0
        self.rejected = 0

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    def _count_rejected(self) -> None:
        # Backpressure used to be visible only to the caller; the registry
        # counter makes it a first-class signal (--metrics-json, gateway
        # admission dashboards). Counts failed put ATTEMPTS, same as the
        # local ``rejected`` field it mirrors.
        self.rejected += 1
        get_registry().counter("queue.rejected_total").inc()

    def _admit(self, req: RenderRequest) -> None:
        if req.enqueue_time is None:
            req = dataclasses.replace(req, enqueue_time=self._clock())
        stamps = getattr(req, "stamps", None)   # duck-typed request stubs
        if stamps is not None:
            stamps.setdefault("enqueue", req.enqueue_time)
        self._items.append(req)
        self.accepted += 1
        self._cond.notify_all()

    def put(self, req: RenderRequest, timeout: Optional[float] = None) -> bool:
        """Enqueue; block up to ``timeout`` while full. Returns False (and
        counts a rejection) if the queue stayed full — the backpressure
        signal callers must handle."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._cond:
            while len(self._items) >= self.maxsize and not self._closed:
                remaining = None if deadline is None else deadline - self._clock()
                if remaining is not None and remaining <= 0:
                    self._count_rejected()
                    return False
                self._cond.wait(remaining)
            if self._closed:
                raise QueueClosed("put() on a closed queue")
            self._admit(req)
            return True

    def put_nowait(self, req: RenderRequest) -> None:
        """Enqueue or raise ``QueueFull`` immediately."""
        with self._cond:
            if self._closed:
                raise QueueClosed("put_nowait() on a closed queue")
            if len(self._items) >= self.maxsize:
                self._count_rejected()
                raise QueueFull(f"queue at depth {self.maxsize}")
            self._admit(req)

    def try_put(self, req: RenderRequest) -> bool:
        """Non-raising ``put_nowait`` for poll-style callers."""
        try:
            self.put_nowait(req)
            return True
        except QueueFull:
            return False

    def drain(self, max_n: Optional[int] = None) -> List[RenderRequest]:
        """Dequeue up to ``max_n`` requests without blocking (FIFO order)."""
        with self._cond:
            n = len(self._items) if max_n is None else min(max_n, len(self._items))
            out, self._items = self._items[:n], self._items[n:]
            if out:
                self._cond.notify_all()
            return out

    def get_batch(
        self, max_n: Optional[int] = None, timeout: Optional[float] = None
    ) -> List[RenderRequest]:
        """Blocking ``drain``: wait up to ``timeout`` for at least one
        request; returns [] on timeout or when closed and empty."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._cond:
            while not self._items and not self._closed:
                remaining = None if deadline is None else deadline - self._clock()
                if remaining is not None and remaining <= 0:
                    return []
                self._cond.wait(remaining)
            n = len(self._items) if max_n is None else min(max_n, len(self._items))
            out, self._items = self._items[:n], self._items[n:]
            if out:
                self._cond.notify_all()
            return out

    def close(self) -> None:
        """Stop admissions and wake all waiters; pending items still drain."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed
