"""Resolution-bucketed batching scheduler (DESIGN.md §9).

Groups pending ``RenderRequest``s into buckets keyed by the static jit
signature (scene id, RenderConfig, camera geometry) so that EVERY dispatch
hits one cached executable from core/pipeline.py — mixing resolutions,
backends, or tile/group configs in a batch would force a recompile, which is
the one thing a serving hot loop must never do. ``RenderConfig.scene_shards``
rides inside the config, so the gaussian-sharded layout of a scene is
selectable per request signature with no scheduler changes: replicated and
sharded dispatches of the same scene land in different buckets by
construction (DESIGN.md §10).

Flush policy (the classic batching latency/throughput dial):
  * a bucket flushes immediately when it reaches ``max_batch`` requests;
  * otherwise it flushes once its OLDEST request has waited ``max_wait``
    seconds (checked by ``poll``), bounding the batching delay any single
    request pays.

Pure Python, no jax: the scheduler manipulates request lists and timestamps
only. The clock is injectable so tests drive time deterministically. The
ragged-batch padding arithmetic for device sharding lives here too
(``padded_size``/``pad_indices``) so it is testable without devices; the
array-level padding built on it is in serving/sharded.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.serving.queue import RenderRequest


@dataclasses.dataclass
class Bucket:
    """Requests sharing one executable signature, oldest first."""

    signature: tuple
    requests: List[RenderRequest]
    created_at: float           # arrival of the oldest (first) request

    def __len__(self) -> int:
        return len(self.requests)

    def age(self, now: float) -> float:
        return now - self.created_at


class BucketingScheduler:
    """Accumulates requests into signature buckets; emits flush-ready ones.

    Not thread-safe by itself: the server's driver loop is the single
    producer/consumer (the thread-safe boundary is the RequestQueue).
    """

    def __init__(
        self,
        max_batch: int = 8,
        max_wait: float = 0.05,
        clock: Optional[Callable[[], float]] = None,
    ):
        if max_batch <= 0:
            raise ValueError(f"max_batch must be positive, got {max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.max_batch = max_batch
        self.max_wait = max_wait
        self._clock = clock or time.monotonic
        self._buckets: Dict[tuple, Bucket] = {}

    @property
    def pending(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    def add(self, req: RenderRequest, now: Optional[float] = None) -> List[Bucket]:
        """File a request under its signature; returns the buckets this add
        made full (at most one) so the caller can dispatch without waiting
        for the next poll."""
        now = self._clock() if now is None else now
        sig = req.signature()
        bucket = self._buckets.get(sig)
        if bucket is None:
            bucket = self._buckets[sig] = Bucket(sig, [], now)
        stamps = getattr(req, "stamps", None)   # duck-typed request stubs
        if stamps is not None:
            stamps.setdefault("batch_form", now)
        bucket.requests.append(req)
        if len(bucket) >= self.max_batch:
            del self._buckets[sig]
            return [bucket]
        return []

    def poll(self, now: Optional[float] = None) -> List[Bucket]:
        """Flush every bucket whose oldest request has waited max_wait."""
        now = self._clock() if now is None else now
        due = [sig for sig, b in self._buckets.items() if b.age(now) >= self.max_wait]
        return [self._buckets.pop(sig) for sig in due]

    def flush_all(self) -> List[Bucket]:
        """Flush everything (shutdown / drain)."""
        out = list(self._buckets.values())
        self._buckets.clear()
        return out


# ---------------------------------------------------------------------------
# Ragged-batch padding arithmetic (device sharding support)
# ---------------------------------------------------------------------------


def padded_size(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` >= n (n >= 1): the batch size a 1-D
    device mesh of that many devices can split evenly."""
    if n <= 0:
        raise ValueError(f"batch size must be positive, got {n}")
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    return ((n + multiple - 1) // multiple) * multiple


def pad_indices_to(n: int, target: int) -> List[int]:
    """Index vector padding n lanes to exactly ``target``: [0..n-1] + [n-1]*pad.

    Replicating the LAST real camera (rather than inventing a null pose)
    keeps the padded rows inside the numerically-exercised envelope; the
    padded tail is sliced off after the dispatch, so correctness needs only
    the round-trip ``pad_indices_to(n, t)[:n] == list(range(n))`` — which
    makes padding mask-correct by construction (tested without jax). This is
    THE pad policy: serving/sharded.py builds its array-level gather from
    this vector."""
    if n <= 0:
        raise ValueError(f"batch size must be positive, got {n}")
    if target < n:
        raise ValueError(f"cannot pad {n} lanes down to {target}")
    return list(range(n)) + [n - 1] * (target - n)


def pad_indices(n: int, multiple: int) -> List[int]:
    """``pad_indices_to`` with the target rounded up to ``multiple``."""
    return pad_indices_to(n, padded_size(n, multiple))
