"""Render-serving subsystem: queue -> bucketing -> sharded dispatch.

Layering (DESIGN.md §9): ``queue``, ``bucketing`` and ``stats`` are pure
Python (no jax) so the admission/scheduling layer imports and tests anywhere;
``sharded`` and ``server`` touch jax and are therefore re-exported lazily —
importing ``repro.serving`` (or any pure module) must not initialize device
state.
"""
from repro.serving.bucketing import (
    Bucket,
    BucketingScheduler,
    pad_indices,
    pad_indices_to,
    padded_size,
)
from repro.serving.queue import QueueClosed, QueueFull, RenderRequest, RequestQueue
from repro.serving.stats import BucketStats, ServingStats, cache_delta, percentile

_LAZY = {
    "render_batch_sharded": "repro.serving.sharded",
    "pad_camera_batch": "repro.serving.sharded",
    "shard_scene_cached": "repro.serving.sharded",
    "acquire_scene_layout": "repro.serving.sharded",
    "release_scene_layout": "repro.serving.sharded",
    "evict_scene_layouts": "repro.serving.sharded",
    "RenderServer": "repro.serving.server",
    "RequestResult": "repro.serving.server",
    "poisson_arrivals": "repro.serving.server",
}

__all__ = [
    "Bucket",
    "BucketingScheduler",
    "BucketStats",
    "QueueClosed",
    "QueueFull",
    "RenderRequest",
    "RequestQueue",
    "ServingStats",
    "cache_delta",
    "pad_indices",
    "pad_indices_to",
    "padded_size",
    "percentile",
    *sorted(_LAZY),
]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
