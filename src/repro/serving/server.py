"""The render server: a thin driver loop over shared engine handles.

Single driver loop, three stages (DESIGN.md §9/§11):

  submit() --> RequestQueue --> BucketingScheduler --> Renderer.render_batch
   (bounded, backpressure)      (one bucket per jit       (ONE committed handle
                                 signature; max-batch /    per (scene, config);
                                 max-wait flush)           fixed dispatch shape)

Scene placement, mesh layout, and the compiled-renderer caches all live in
the ``repro.engine.Renderer`` handles the server opens lazily per
(scene id, config) — the server itself only schedules: it drains the queue
into signature buckets and hands each bucket to the right handle. The loop
is synchronous and single-threaded on the dispatch side — device work is
serialized anyway, and keeping scheduling single-threaded makes the latency
accounting exact. Producers may submit from other threads (the queue is the
thread-safe boundary) or inline via ``run(load)`` which replays a timed
load (e.g. ``poisson_arrivals``) in real time. (A per-scene futures
front-end without the multi-scene admission layer is just
``Renderer.submit`` — the server adds scenes, admission screening, and
serving stats on top.)

Every completed request yields a ``RequestResult`` with the rendered image
(host numpy), its end-to-end latency, and the bucket it rode in;
``RenderServer.stats`` aggregates per-bucket latency/throughput/cache-hit
counters (serving/stats.py).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.gaussians import GaussianScene
from repro.core.pipeline import CameraBatch, render_cache_info
from repro.obs import emit_request_spans, get_tracer
from repro.residency import ResidencyManager
from repro.serving.bucketing import Bucket, BucketingScheduler, padded_size
from repro.serving.queue import RenderRequest, RequestQueue
from repro.serving.stats import ServingStats


@dataclasses.dataclass
class RequestResult:
    request_id: int
    image: np.ndarray            # (H, W, 3) host copy
    latency_s: float             # completion - enqueue (queue + batch + render)
    batch_size: int              # how many requests shared the dispatch
    signature: tuple
    deadline_missed: bool = False


class RenderServer:
    """Serves render requests against a registry of scenes.

    ``mesh=None`` shards each dispatch over all local devices (built lazily
    on first dispatch so constructing a server never touches device state);
    ``scene_shards = D > 1`` builds the 2-D (data, model) render mesh and
    the handles commit scenes gaussian-sharded over 'model' (DESIGN.md §10).
    Requests choose their own layout via ``cfg.scene_shards`` — it is part
    of the bucket signature, so replicated and sharded dispatches of the
    same scene never mix in a batch; a request's shard count must be 1 or
    match the server's mesh. ``device_budget_mb`` seeds the server's
    :class:`~repro.residency.ResidencyManager` (DESIGN.md §17): scenes
    that fit individually but not together page in/out LRU against the
    budget (bitwise-invisibly) instead of refusing to commit — only a
    scene too big to fit even alone still fails fast; ``prefetch=False``
    disables the admission-time page-in. ``autotune=True`` opens every handle
    with ``tile_params='auto'`` (DESIGN.md §13): the first dispatch of each
    (scene, config) pays a tuning sweep — or hits the persisted autotune
    cache — and serves the tuned tiling from then on (``autotune_opts`` is
    forwarded to ``repro.autotune.autotune``). Close the server (or use it
    as a context manager) to close its handles.
    """

    def __init__(
        self,
        scenes: Mapping[str, GaussianScene],
        *,
        mesh=None,
        max_batch: int = 8,
        max_wait: float = 0.05,
        queue_depth: int = 64,
        scene_shards: int = 1,
        device_budget_mb: Optional[float] = None,
        autotune: bool = False,
        autotune_opts: Optional[dict] = None,
        stream_cache_frames: int = 32,
        spec_depth: int = 2,
        speculate: bool = True,
        prefetch: bool = True,
        clock=time.monotonic,
    ):
        self.scenes = dict(scenes)
        self._mesh = mesh
        self.scene_shards = scene_shards
        self.device_budget_mb = device_budget_mb
        self.autotune = autotune
        self.autotune_opts = autotune_opts
        self.stream_cache_frames = stream_cache_frames
        self.spec_depth = spec_depth
        self.speculate = speculate
        self.prefetch = prefetch
        self._clock = clock
        self.queue = RequestQueue(queue_depth, clock=clock)
        self.scheduler = BucketingScheduler(max_batch, max_wait, clock=clock)
        self.stats = ServingStats()
        self.results: Dict[int, RequestResult] = {}
        # ONE residency manager for every handle this server opens
        # (DESIGN.md §17): device copies dedupe per (scene, layout, mesh)
        # — the committed-scene sharing across configs — and, under a
        # device_budget_mb, an over-budget commit evicts cold scenes
        # instead of failing fast (a single scene that cannot fit even
        # alone still raises from engine.open).
        self.residency = ResidencyManager(
            budget_mb=device_budget_mb, name="server"
        )
        # The server lock: commit()/stream_for()/close() all mutate the
        # handle registry — without it, commit() could hand out a handle
        # while close() tears the map down, leaking its jit cache and
        # scene layouts. Reentrant: stream_for -> commit nests.
        self._lock = threading.RLock()
        self._server_closed = False
        self._renderers: Dict[Tuple[str, object], object] = {}
        # Stream sessions (DESIGN.md §15): one StreamRenderer per
        # (scene, cfg, stream_id), opened lazily on the stream's first
        # frame over the shared committed handle; the handle's close()
        # closes its streams.
        self._streams: Dict[Tuple[str, object, str], object] = {}

    @property
    def mesh(self):
        if self._mesh is None:
            import jax

            from repro.launch.mesh import make_render_mesh, render_mesh_shards

            # Logical shard axis when D does not divide the device count
            # (single-device tests still serve sharded layouts correctly —
            # they just do not save per-device memory).
            self._mesh = make_render_mesh(
                scene_shards=render_mesh_shards(
                    len(jax.devices()), self.scene_shards
                )
            )
        return self._mesh

    # -- admission ----------------------------------------------------------

    def _layout_ok(self, req: RenderRequest) -> bool:
        """A request's gaussian layout must be replicated (1) or match the
        server's configured shard count — a mismatched layout would raise
        inside the dispatch and kill the loop for everyone behind it, so it
        is screened at admission (pure Python, no device touch)."""
        return getattr(req.cfg, "scene_shards", 1) in (1, self.scene_shards)

    def submit(self, req: RenderRequest) -> bool:
        """Non-blocking admission; False = backpressure (queue at depth).
        Raises KeyError for an unknown scene and ValueError for a scene-shard
        layout the server's mesh cannot serve (caller bugs, not load)."""
        if req.scene_id not in self.scenes:
            raise KeyError(f"unknown scene {req.scene_id!r}")
        if not self._layout_ok(req):
            raise ValueError(
                f"request {req.request_id} wants scene_shards="
                f"{getattr(req.cfg, 'scene_shards', 1)} but this server "
                f"serves 1 or {self.scene_shards}"
            )
        ok = self.queue.try_put(req)
        if not ok:
            self.stats.count_rejected()
        elif self.prefetch:
            self._prefetch(req)
        return ok

    def _prefetch(self, req: RenderRequest) -> None:
        """Admission-time prefetch (DESIGN.md §17): if the admitted
        request's scene is already committed but paged out, page it back
        in NOW so the dispatch that follows finds it resident. Only
        already-committed handles are touched — admission stays cheap and
        raise-free (a first-time scene pays its commit at dispatch, as
        before)."""
        with self._lock:
            handle = self._renderers.get((req.scene_id, req.cfg))
            if handle is None or handle.closed or handle.resident:
                return
            try:
                handle.prefetch()
            except Exception:       # noqa: BLE001 — prefetch is advisory;
                pass                # the dispatch path surfaces real errors

    # -- committed handles --------------------------------------------------

    @property
    def committed_scene_ids(self) -> frozenset:
        """Scenes with at least one committed handle — the gateway tier's
        scene-affinity signal (route to the worker already holding the
        scene on device before paying a commit elsewhere)."""
        with self._lock:
            return frozenset(sid for sid, _cfg in self._renderers)

    @property
    def resident_scene_ids(self) -> frozenset:
        """Committed scenes whose device copy is resident RIGHT NOW (not
        paged out by the residency manager) — the gateway tier's
        residency-aware placement signal: a resident worker serves the
        request without paying a page-in."""
        with self._lock:
            return frozenset(
                sid for (sid, _cfg), h in self._renderers.items()
                if not h.closed and h.resident
            )

    def commit(self, scene_id: str, cfg):
        """The shared engine handle for ``(scene_id, cfg)``, opened on first
        use. Public so drivers can pre-commit scenes before taking load — a
        scene too big to fit the budget even ALONE still fails fast here
        (``engine.open`` raises); scenes that fit individually but not
        together page in and out through the server's residency manager
        instead of failing (DESIGN.md §17).

        Handles are per (scene, config) — the compiled programs differ —
        but the committed DEVICE scene is shared per (scene, layout): the
        residency manager dedupes entries, so two configs over one scene
        cost one scene copy, not two. Raises RuntimeError after close()."""
        with self._lock:
            if self._server_closed:
                raise RuntimeError("RenderServer is closed")
            key = (scene_id, cfg)
            handle = self._renderers.get(key)
            if handle is None:
                from repro import engine

                handle = engine.open(
                    self.scenes[scene_id], cfg,
                    mesh=self.mesh,
                    residency=self.residency,
                    tile_params="auto" if self.autotune else None,
                    autotune_opts=self.autotune_opts,
                )
                self._renderers[key] = handle
            return handle

    def stream_for(self, req: RenderRequest):
        """The stream session serving ``req``'s (scene, cfg, stream_id),
        opened on first use over the shared committed handle."""
        with self._lock:
            key = (req.scene_id, req.cfg, req.stream_id)
            stream = self._streams.get(key)
            if stream is None or stream.closed:
                handle = self.commit(req.scene_id, req.cfg)
                stream = handle.open_stream(
                    cache_frames=self.stream_cache_frames,
                    spec_depth=self.spec_depth,
                    speculate=self.speculate,
                )
                self._streams[key] = stream
            return stream

    def stream_stats(self) -> Dict[str, dict]:
        """Per-stream session counters keyed by registry cache name."""
        return {
            s.name: s.stats()
            for s in self._streams.values() if not s.closed
        }

    # -- scheduling / dispatch ----------------------------------------------

    def _pump_queue(self, now: Optional[float] = None) -> int:
        """Drain the queue into buckets, dispatching any bucket that fills
        to max_batch (partial buckets keep waiting)."""
        n = 0
        for req in self.queue.drain():
            for bucket in self.scheduler.add(req, now):
                self._dispatch(bucket)
                n += 1
        return n

    def step(self, now: Optional[float] = None) -> int:
        """One scheduler turn: pump the queue, then dispatch buckets past
        max_wait. Returns the number of dispatches."""
        n = self._pump_queue(now)
        for bucket in self.scheduler.poll(now):
            self._dispatch(bucket)
            n += 1
        return n

    def drain(self) -> None:
        """Flush everything pending (shutdown path): remaining queue items
        are bucketed and every bucket dispatches regardless of age."""
        while len(self.queue) or self.scheduler.pending:
            self._pump_queue()
            for bucket in self.scheduler.flush_all():
                self._dispatch(bucket)

    def _dispatch(self, bucket: Bucket) -> None:
        reqs = bucket.requests
        if getattr(reqs[0], "stream_id", None) is not None:
            self._dispatch_stream(bucket)
            return
        handle = self.commit(reqs[0].scene_id, reqs[0].cfg)
        batch = CameraBatch.from_cameras([r.camera for r in reqs])
        # Fixed dispatch shape: every bucket of a signature pads to
        # max_batch (rounded to the camera-lane count — the mesh's DATA
        # extent), so ragged max_wait flushes reuse the ONE compiled program
        # instead of tracing a new shape (DESIGN.md §9 invariant).
        from repro.sharding.policies import data_extent

        shape = padded_size(self.scheduler.max_batch, data_extent(self.mesh))

        before = render_cache_info()
        t0 = self._clock()
        out = handle.render_batch(batch, pad_to=shape)
        images = np.asarray(out.image)   # blocks until device work completes
        t1 = self._clock()
        after = render_cache_info()

        tracer = get_tracer()
        if tracer.enabled:
            tracer.complete(
                "serve/dispatch", t0, t1, category="serving",
                args={"batch_size": len(reqs), "padded": shape,
                      "signature": repr(bucket.signature)},
            )
        latencies = [t1 - r.enqueue_time for r in reqs]
        self.stats.record_dispatch(
            bucket.signature,
            batch_size=len(reqs),
            padded_size=shape,
            render_s=t1 - t0,
            latencies_s=latencies,
            cache_before=before,
            cache_after=after,
        )
        for req, img, lat in zip(reqs, images, latencies):
            missed = req.deadline is not None and t1 > req.deadline
            if missed:
                self.stats.count_deadline_miss()
            self.results[req.request_id] = RequestResult(
                request_id=req.request_id,
                image=img,
                latency_s=lat,
                batch_size=len(reqs),
                signature=bucket.signature,
                deadline_missed=missed,
            )
            stamps = getattr(req, "stamps", None)
            if stamps is not None:
                stamps["dispatch"] = t0
                stamps["device_done"] = t1
                stamps["resolve"] = self._clock()
                emit_request_spans(
                    tracer, req.request_id, stamps,
                    args={"scene_id": req.scene_id},
                )

    def _dispatch_stream(self, bucket: Bucket) -> None:
        """Dispatch a stream bucket: frames run IN ORDER through the
        stream's session (exact-reuse cache + speculation), one frame per
        device dispatch — the signature guarantees every request here
        belongs to one stream, and queue FIFO + in-order bucket appends
        preserved the frame order. Output is bitwise-identical to the
        stateless batch path (the session reuses frontends only on exact
        pose-key hits; tests/test_stream.py)."""
        reqs = bucket.requests
        stream = self.stream_for(reqs[0])

        before = render_cache_info()
        t0 = self._clock()
        images = [np.asarray(stream.render(r.camera).image) for r in reqs]
        t1 = self._clock()
        after = render_cache_info()

        tracer = get_tracer()
        if tracer.enabled:
            tracer.complete(
                "serve/dispatch", t0, t1, category="serving",
                args={"batch_size": len(reqs), "padded": len(reqs),
                      "stream": stream.name,
                      "signature": repr(bucket.signature)},
            )
        latencies = [t1 - r.enqueue_time for r in reqs]
        self.stats.record_dispatch(
            bucket.signature,
            batch_size=len(reqs),
            padded_size=len(reqs),     # per-frame dispatch: no pad lanes
            render_s=t1 - t0,
            latencies_s=latencies,
            cache_before=before,
            cache_after=after,
        )
        for req, img, lat in zip(reqs, images, latencies):
            missed = req.deadline is not None and t1 > req.deadline
            if missed:
                self.stats.count_deadline_miss()
            self.results[req.request_id] = RequestResult(
                request_id=req.request_id,
                image=img,
                latency_s=lat,
                batch_size=len(reqs),
                signature=bucket.signature,
                deadline_missed=missed,
            )
            stamps = getattr(req, "stamps", None)
            if stamps is not None:
                stamps["dispatch"] = t0
                stamps["device_done"] = t1
                stamps["resolve"] = self._clock()
                emit_request_spans(
                    tracer, req.request_id, stamps,
                    args={"scene_id": req.scene_id,
                          "stream": stream.name},
                )

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Close every committed handle (releasing their jit caches, scene
        layouts, and residency entries — each handle also closes its
        stream sessions). TERMINAL: a later ``commit()`` raises
        RuntimeError — the server lock makes close-vs-commit a clean
        ordering instead of a race that could hand out a handle the
        teardown never closes (leaked jit cache + layouts). Idempotent."""
        with self._lock:
            self._server_closed = True
            while self._renderers:
                self._renderers.pop(next(iter(self._renderers))).close()
            self._streams.clear()

    def __enter__(self) -> "RenderServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- timed replay --------------------------------------------------------

    def run(
        self,
        load: Iterable[Tuple[float, RenderRequest]],
        realtime: bool = True,
    ) -> Dict[int, RequestResult]:
        """Serve a timed load of ``(arrival_offset_s, request)`` pairs.

        ``realtime=True`` sleeps the inter-arrival gaps (servicing due
        buckets while waiting) so max-wait flushes behave as in production —
        it requires the default wall clock (an injected fake clock never
        advances through ``time.sleep`` and would spin forever; fakes are
        for the scheduler unit tests). ``realtime=False`` enqueues the whole
        backlog and drains it (closed-loop throughput mode: buckets fill to
        max_batch regardless of max_wait — what bench_serving measures).
        Unknown-scene and unservable-layout (scene_shards mismatch) requests
        in a load are counted as rejections and skipped rather than killing
        the requests behind them. Returns the results map; ``stats.wall_s``
        is stamped on exit.
        """
        t_start = self._clock()
        for offset, req in load:
            if req.scene_id not in self.scenes or not self._layout_ok(req):
                self.stats.count_rejected()
                continue
            if realtime:
                while self._clock() - t_start < offset:
                    self.step()
                    gap = offset - (self._clock() - t_start)
                    if gap > 0:
                        time.sleep(min(gap, max(self.scheduler.max_wait, 1e-3) / 4))
            if not self.queue.try_put(req):
                # Backpressure inline: service the backlog, then retry once;
                # a second failure is a real rejection.
                self._pump_queue()
                if not self.queue.try_put(req):
                    self.stats.count_rejected()
                elif self.prefetch:
                    self._prefetch(req)
            elif self.prefetch:
                self._prefetch(req)
            if realtime:
                self.step()
        self.drain()
        self.stats.wall_s = self._clock() - t_start
        return self.results


def poisson_arrivals(
    n: int, rate_hz: float, seed: int = 0
) -> List[float]:
    """n arrival offsets with exponential inter-arrival gaps (Poisson
    process at ``rate_hz``) — the synthetic open-loop load for the CLI and
    the serving benchmark."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    return np.cumsum(gaps).tolist()
