"""The render server: queue -> bucketer -> sharded dispatch (DESIGN.md §9).

Single driver loop, three stages:

  submit() --> RequestQueue --> BucketingScheduler --> _dispatch()
   (bounded, backpressure)      (one bucket per jit       (render_batch_sharded,
                                 signature; max-batch /    ONE cached executable
                                 max-wait flush)           per bucket signature)

The loop is synchronous and single-threaded on the dispatch side — device
work is serialized anyway, and keeping scheduling single-threaded makes the
latency accounting exact. Producers may submit from other threads (the queue
is the thread-safe boundary) or inline via ``run(load)`` which replays a
timed load (e.g. ``poisson_arrivals``) in real time.

Every completed request yields a ``RequestResult`` with the rendered image
(host numpy), its end-to-end latency, and the bucket it rode in;
``RenderServer.stats`` aggregates per-bucket latency/throughput/cache-hit
counters (serving/stats.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.gaussians import GaussianScene
from repro.core.pipeline import CameraBatch, render_cache_info
from repro.serving.bucketing import Bucket, BucketingScheduler, padded_size
from repro.serving.queue import RenderRequest, RequestQueue
from repro.serving.sharded import render_batch_sharded
from repro.serving.stats import ServingStats


@dataclasses.dataclass
class RequestResult:
    request_id: int
    image: np.ndarray            # (H, W, 3) host copy
    latency_s: float             # completion - enqueue (queue + batch + render)
    batch_size: int              # how many requests shared the dispatch
    signature: tuple
    deadline_missed: bool = False


class RenderServer:
    """Serves render requests against a registry of scenes.

    ``mesh=None`` shards each dispatch over all local devices (1-D mesh,
    built lazily on first dispatch so constructing a server never touches
    device state).
    """

    def __init__(
        self,
        scenes: Mapping[str, GaussianScene],
        *,
        mesh=None,
        max_batch: int = 8,
        max_wait: float = 0.05,
        queue_depth: int = 64,
        clock=time.monotonic,
    ):
        self.scenes = dict(scenes)
        self._mesh = mesh
        self._clock = clock
        self.queue = RequestQueue(queue_depth, clock=clock)
        self.scheduler = BucketingScheduler(max_batch, max_wait, clock=clock)
        self.stats = ServingStats()
        self.results: Dict[int, RequestResult] = {}
        self._committed: Dict[str, GaussianScene] = {}

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_render_mesh

            self._mesh = make_render_mesh()
        return self._mesh

    # -- admission ----------------------------------------------------------

    def submit(self, req: RenderRequest) -> bool:
        """Non-blocking admission; False = backpressure (queue at depth).
        Raises KeyError for an unknown scene (a caller bug, not load)."""
        if req.scene_id not in self.scenes:
            raise KeyError(f"unknown scene {req.scene_id!r}")
        ok = self.queue.try_put(req)
        if not ok:
            self.stats.count_rejected()
        return ok

    # -- scheduling / dispatch ----------------------------------------------

    def _pump_queue(self, now: Optional[float] = None) -> int:
        """Drain the queue into buckets, dispatching any bucket that fills
        to max_batch (partial buckets keep waiting)."""
        n = 0
        for req in self.queue.drain():
            for bucket in self.scheduler.add(req, now):
                self._dispatch(bucket)
                n += 1
        return n

    def step(self, now: Optional[float] = None) -> int:
        """One scheduler turn: pump the queue, then dispatch buckets past
        max_wait. Returns the number of dispatches."""
        n = self._pump_queue(now)
        for bucket in self.scheduler.poll(now):
            self._dispatch(bucket)
            n += 1
        return n

    def drain(self) -> None:
        """Flush everything pending (shutdown path): remaining queue items
        are bucketed and every bucket dispatches regardless of age."""
        while len(self.queue) or self.scheduler.pending:
            self._pump_queue()
            for bucket in self.scheduler.flush_all():
                self._dispatch(bucket)

    def _scene_on_mesh(self, scene_id: str) -> GaussianScene:
        """Scene committed (replicated) to the mesh ONCE; every dispatch then
        reuses the device copy instead of re-transferring it."""
        if scene_id not in self._committed:
            import jax
            from jax.sharding import NamedSharding

            from repro.sharding.policies import render_replicated_pspec

            self._committed[scene_id] = jax.device_put(
                self.scenes[scene_id],
                NamedSharding(self.mesh, render_replicated_pspec()),
            )
        return self._committed[scene_id]

    def _dispatch(self, bucket: Bucket) -> None:
        reqs = bucket.requests
        scene = self._scene_on_mesh(reqs[0].scene_id)
        cfg = reqs[0].cfg
        batch = CameraBatch.from_cameras([r.camera for r in reqs])
        # Fixed dispatch shape: every bucket of a signature pads to
        # max_batch (rounded to the device count), so ragged max_wait
        # flushes reuse the ONE compiled program instead of tracing a new
        # shape (DESIGN.md §9 invariant).
        shape = padded_size(self.scheduler.max_batch, self.mesh.size)

        before = render_cache_info()
        t0 = self._clock()
        out = render_batch_sharded(
            scene, batch, cfg, mesh=self.mesh, pad_to=shape
        )
        images = np.asarray(out.image)   # blocks until device work completes
        t1 = self._clock()
        after = render_cache_info()

        latencies = [t1 - r.enqueue_time for r in reqs]
        self.stats.record_dispatch(
            bucket.signature,
            batch_size=len(reqs),
            padded_size=shape,
            render_s=t1 - t0,
            latencies_s=latencies,
            cache_before=before,
            cache_after=after,
        )
        for req, img, lat in zip(reqs, images, latencies):
            missed = req.deadline is not None and t1 > req.deadline
            if missed:
                self.stats.deadline_misses += 1
            self.results[req.request_id] = RequestResult(
                request_id=req.request_id,
                image=img,
                latency_s=lat,
                batch_size=len(reqs),
                signature=bucket.signature,
                deadline_missed=missed,
            )

    # -- timed replay --------------------------------------------------------

    def run(
        self,
        load: Iterable[Tuple[float, RenderRequest]],
        realtime: bool = True,
    ) -> Dict[int, RequestResult]:
        """Serve a timed load of ``(arrival_offset_s, request)`` pairs.

        ``realtime=True`` sleeps the inter-arrival gaps (servicing due
        buckets while waiting) so max-wait flushes behave as in production —
        it requires the default wall clock (an injected fake clock never
        advances through ``time.sleep`` and would spin forever; fakes are
        for the scheduler unit tests). ``realtime=False`` enqueues the whole
        backlog and drains it (closed-loop throughput mode: buckets fill to
        max_batch regardless of max_wait — what bench_serving measures).
        Unknown-scene requests in a load are counted as rejections and
        skipped rather than killing the requests behind them. Returns the
        results map; ``stats.wall_s`` is stamped on exit.
        """
        t_start = self._clock()
        for offset, req in load:
            if req.scene_id not in self.scenes:
                self.stats.count_rejected()
                continue
            if realtime:
                while self._clock() - t_start < offset:
                    self.step()
                    gap = offset - (self._clock() - t_start)
                    if gap > 0:
                        time.sleep(min(gap, max(self.scheduler.max_wait, 1e-3) / 4))
            if not self.queue.try_put(req):
                # Backpressure inline: service the backlog, then retry once;
                # a second failure is a real rejection.
                self._pump_queue()
                if not self.queue.try_put(req):
                    self.stats.count_rejected()
            if realtime:
                self.step()
        self.drain()
        self.stats.wall_s = self._clock() - t_start
        return self.results


def poisson_arrivals(
    n: int, rate_hz: float, seed: int = 0
) -> List[float]:
    """n arrival offsets with exponential inter-arrival gaps (Poisson
    process at ``rate_hz``) — the synthetic open-loop load for the CLI and
    the serving benchmark."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    return np.cumsum(gaps).tolist()
