"""Per-bucket serving statistics: latency, throughput, cache hits.

Pure Python (no jax): the server records one ``record_dispatch`` per batch
with walltimes measured around the actual device work, and folds in the
engine's executable-cache deltas (``render_cache_info`` dicts) so the serving
counters and the CLI ``--stats`` output agree on what a "cache hit" is — a
dispatch that reused a compiled renderer.

Latency is request-level (completion - enqueue), so it includes queueing and
batching delay, not just device time; p50/p99 over those latencies plus
end-to-end FPS are the numbers bench_serving.py compares against the naive
per-request loop.

Memory + concurrency (DESIGN.md §14): latencies live in bounded reservoir
histograms (``repro.obs.metrics.Histogram`` — exact percentiles up to the
reservoir cap, uniform sampling beyond it), so a long-lived server stops
growing one float per request; and ALL mutation (dispatch folds, rejections,
deadline misses) goes through one lock — ``Renderer.submit()``'s worker
thread and a driver loop may fold concurrently. Every fold also publishes
into the process metrics registry (``serving.*`` counters/histograms), which
is what ``--metrics-json`` snapshots.
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
from typing import Dict, List, Optional

from repro.obs.metrics import Histogram, MetricsRegistry, get_registry

#: Reservoir capacity for latency histograms: percentiles are EXACT for any
#: bucket that has seen up to this many requests, sampled (uniformly, with a
#: deterministic seed) beyond it.
LATENCY_RESERVOIR = 4096


def percentile(values: List[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]); nan for no samples.

    NOTE the empty-input contract differs from ``repro.obs.metrics
    .percentile`` (0.0): serving percentiles must be NON-finite when nothing
    completed — launch/render_serve.py's CI exit contract keys on a finite
    p99, and an empty run reporting 0.0 would pass it.
    """
    if not values:
        return math.nan
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


def _latency_histogram() -> Histogram:
    return Histogram(cap=LATENCY_RESERVOIR)


@dataclasses.dataclass
class BucketStats:
    """Counters for one executable signature."""

    signature: tuple
    requests: int = 0
    batches: int = 0
    padded: int = 0              # wasted lanes added for device divisibility
    render_s: float = 0.0        # device walltime across dispatches
    latency: Histogram = dataclasses.field(default_factory=_latency_histogram)
    cache_hits: int = 0          # dispatches that reused a compiled renderer
    cache_misses: int = 0        # dispatches that compiled

    @property
    def latencies_s(self) -> List[float]:
        """The latency RESERVOIR (bounded view; the full stream once the
        bucket exceeds LATENCY_RESERVOIR requests — ``latency.count`` keeps
        the exact total)."""
        return self.latency.values()

    @property
    def mean_batch(self) -> float:
        return self.requests / self.batches if self.batches else math.nan

    def to_dict(self) -> dict:
        lat = self.latency.values()
        return {
            "signature": repr(self.signature),
            "requests": self.requests,
            "batches": self.batches,
            "mean_batch": self.mean_batch,
            "padded": self.padded,
            "render_s": self.render_s,
            "p50_ms": percentile(lat, 50) * 1e3,
            "p99_ms": percentile(lat, 99) * 1e3,
            "latency_count": self.latency.count,
            "latency_sampled": self.latency.sampled,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
        }


def cache_delta(before: dict, after: dict) -> Dict[str, int]:
    """hits/misses deltas summed over EVERY renderer cache reported by
    ``render_cache_info()`` — the single/batch executable caches plus any
    registered auxiliary cache (e.g. the sharded scene-layout cache).
    Tolerates caches that registered between the two snapshots."""
    return {
        key: sum(
            after[kind].get(key, 0) - before.get(kind, {}).get(key, 0)
            for kind in after
        )
        for key in ("hits", "misses")
    }


class ServingStats:
    """Aggregates BucketStats across the server's lifetime.

    Thread-safe: one lock guards every mutator — dispatch folds can arrive
    from a driver loop and the futures worker concurrently, and the old
    reject-only lock left ``record_dispatch`` racy. Each fold/rejection also
    publishes ``serving.*`` counters and histograms into ``registry``
    (default: the process-wide ``repro.obs.get_registry()``).
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.buckets: Dict[tuple, BucketStats] = {}
        self.rejected = 0
        self.deadline_misses = 0
        self.wall_s: Optional[float] = None   # stamped by the driver loop
        # Cross-bucket request latencies (bounded reservoir; the per-bucket
        # histograms keep exact counts, this one feeds the aggregate p50/p99).
        self.latency = _latency_histogram()
        self._registry = registry if registry is not None else get_registry()
        self._lock = threading.RLock()

    def count_rejected(self) -> None:
        with self._lock:
            self.rejected += 1
        self._registry.counter("serving.rejected_total").inc()

    def count_deadline_miss(self) -> None:
        with self._lock:
            self.deadline_misses += 1
        self._registry.counter("serving.deadline_misses_total").inc()

    def bucket(self, signature: tuple) -> BucketStats:
        with self._lock:
            if signature not in self.buckets:
                self.buckets[signature] = BucketStats(signature)
            return self.buckets[signature]

    def record_dispatch(
        self,
        signature: tuple,
        batch_size: int,
        padded_size: int,
        render_s: float,
        latencies_s: List[float],
        cache_before: Optional[dict] = None,
        cache_after: Optional[dict] = None,
    ) -> None:
        delta = None
        if cache_before is not None and cache_after is not None:
            delta = cache_delta(cache_before, cache_after)
        with self._lock:
            b = self.bucket(signature)
            b.requests += batch_size
            b.batches += 1
            b.padded += padded_size - batch_size
            b.render_s += render_s
            b.latency.observe_many(latencies_s)
            self.latency.observe_many(latencies_s)
            if delta is not None:
                b.cache_hits += delta["hits"]
                b.cache_misses += delta["misses"]
        reg = self._registry
        reg.counter("serving.requests_total").inc(batch_size)
        reg.counter("serving.batches_total").inc()
        reg.counter("serving.padded_lanes_total").inc(
            padded_size - batch_size)
        if delta is not None:
            reg.counter("serving.cache_hits_total").inc(max(delta["hits"], 0))
            reg.counter("serving.cache_misses_total").inc(
                max(delta["misses"], 0))
        reg.histogram("serving.render_s").observe(render_s)
        lat_h = reg.histogram("serving.latency_s")
        lat_h.observe_many(latencies_s)

    # -- aggregate views ----------------------------------------------------

    @property
    def completed(self) -> int:
        with self._lock:
            return sum(b.requests for b in self.buckets.values())

    def all_latencies(self) -> List[float]:
        """The aggregate latency RESERVOIR (exact below LATENCY_RESERVOIR
        total requests, a uniform sample beyond — ``self.latency.count`` has
        the exact total)."""
        return self.latency.values()

    def fps(self) -> float:
        if not self.wall_s:
            return math.nan
        return self.completed / self.wall_s

    def summary(self) -> dict:
        with self._lock:
            buckets = list(self.buckets.values())
            lat = self.latency.values()
            return {
                "completed": sum(b.requests for b in buckets),
                "rejected": self.rejected,
                "deadline_misses": self.deadline_misses,
                "batches": sum(b.batches for b in buckets),
                "padded": sum(b.padded for b in buckets),
                "cache_hits": sum(b.cache_hits for b in buckets),
                "cache_misses": sum(b.cache_misses for b in buckets),
                "p50_ms": percentile(lat, 50) * 1e3,
                "p99_ms": percentile(lat, 99) * 1e3,
                "wall_s": self.wall_s,
                "fps": self.fps(),
                "buckets": [b.to_dict() for b in buckets],
            }

    def to_json(self, **extra) -> str:
        return json.dumps({**self.summary(), **extra}, indent=2)

    def format(self) -> str:
        s = self.summary()
        wall = f"{s['wall_s']:.2f}s" if s["wall_s"] is not None else "n/a"
        lines = [
            f"served {s['completed']} requests in {s['batches']} batches "
            f"({s['rejected']} rejected, {s['padded']} padded lanes)",
            f"  latency p50={s['p50_ms']:.1f}ms p99={s['p99_ms']:.1f}ms  "
            f"throughput={s['fps']:.1f} fps  wall={wall}",
            f"  executable cache: {s['cache_hits']} hits / "
            f"{s['cache_misses']} misses",
        ]
        for d in sorted(s["buckets"], key=lambda d: -d["requests"]):
            lines.append(
                f"  bucket {d['signature'][:72]}: {d['requests']} reqs / "
                f"{d['batches']} batches (mean {d['mean_batch']:.1f}), "
                f"p99={d['p99_ms']:.1f}ms"
            )
        return "\n".join(lines)
