"""Per-bucket serving statistics: latency, throughput, cache hits.

Pure Python (no jax): the server records one ``record_dispatch`` per batch
with walltimes measured around the actual device work, and folds in the
engine's executable-cache deltas (``render_cache_info`` dicts) so the serving
counters and the CLI ``--stats`` output agree on what a "cache hit" is — a
dispatch that reused a compiled renderer.

Latency is request-level (completion - enqueue), so it includes queueing and
batching delay, not just device time; p50/p99 over those latencies plus
end-to-end FPS are the numbers bench_serving.py compares against the naive
per-request loop.
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
from typing import Dict, List, Optional


def percentile(values: List[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]); nan for no samples."""
    if not values:
        return math.nan
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


@dataclasses.dataclass
class BucketStats:
    """Counters for one executable signature."""

    signature: tuple
    requests: int = 0
    batches: int = 0
    padded: int = 0              # wasted lanes added for device divisibility
    render_s: float = 0.0        # device walltime across dispatches
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    cache_hits: int = 0          # dispatches that reused a compiled renderer
    cache_misses: int = 0        # dispatches that compiled

    @property
    def mean_batch(self) -> float:
        return self.requests / self.batches if self.batches else math.nan

    def to_dict(self) -> dict:
        return {
            "signature": repr(self.signature),
            "requests": self.requests,
            "batches": self.batches,
            "mean_batch": self.mean_batch,
            "padded": self.padded,
            "render_s": self.render_s,
            "p50_ms": percentile(self.latencies_s, 50) * 1e3,
            "p99_ms": percentile(self.latencies_s, 99) * 1e3,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
        }


def cache_delta(before: dict, after: dict) -> Dict[str, int]:
    """hits/misses deltas summed over EVERY renderer cache reported by
    ``render_cache_info()`` — the single/batch executable caches plus any
    registered auxiliary cache (e.g. the sharded scene-layout cache).
    Tolerates caches that registered between the two snapshots."""
    return {
        key: sum(
            after[kind].get(key, 0) - before.get(kind, {}).get(key, 0)
            for kind in after
        )
        for key in ("hits", "misses")
    }


class ServingStats:
    """Aggregates BucketStats across the server's lifetime."""

    def __init__(self):
        self.buckets: Dict[tuple, BucketStats] = {}
        self.rejected = 0
        self.deadline_misses = 0
        self.wall_s: Optional[float] = None   # stamped by the driver loop
        # Dispatch-side counters are driver-thread-only, but rejections come
        # from submit(), which producers may call from many threads.
        self._reject_lock = threading.Lock()

    def count_rejected(self) -> None:
        with self._reject_lock:
            self.rejected += 1

    def bucket(self, signature: tuple) -> BucketStats:
        if signature not in self.buckets:
            self.buckets[signature] = BucketStats(signature)
        return self.buckets[signature]

    def record_dispatch(
        self,
        signature: tuple,
        batch_size: int,
        padded_size: int,
        render_s: float,
        latencies_s: List[float],
        cache_before: Optional[dict] = None,
        cache_after: Optional[dict] = None,
    ) -> None:
        b = self.bucket(signature)
        b.requests += batch_size
        b.batches += 1
        b.padded += padded_size - batch_size
        b.render_s += render_s
        b.latencies_s.extend(latencies_s)
        if cache_before is not None and cache_after is not None:
            delta = cache_delta(cache_before, cache_after)
            b.cache_hits += delta["hits"]
            b.cache_misses += delta["misses"]

    # -- aggregate views ----------------------------------------------------

    @property
    def completed(self) -> int:
        return sum(b.requests for b in self.buckets.values())

    def all_latencies(self) -> List[float]:
        return [t for b in self.buckets.values() for t in b.latencies_s]

    def fps(self) -> float:
        if not self.wall_s:
            return math.nan
        return self.completed / self.wall_s

    def summary(self) -> dict:
        lat = self.all_latencies()
        return {
            "completed": self.completed,
            "rejected": self.rejected,
            "deadline_misses": self.deadline_misses,
            "batches": sum(b.batches for b in self.buckets.values()),
            "padded": sum(b.padded for b in self.buckets.values()),
            "cache_hits": sum(b.cache_hits for b in self.buckets.values()),
            "cache_misses": sum(b.cache_misses for b in self.buckets.values()),
            "p50_ms": percentile(lat, 50) * 1e3,
            "p99_ms": percentile(lat, 99) * 1e3,
            "wall_s": self.wall_s,
            "fps": self.fps(),
            "buckets": [b.to_dict() for b in self.buckets.values()],
        }

    def to_json(self, **extra) -> str:
        return json.dumps({**self.summary(), **extra}, indent=2)

    def format(self) -> str:
        s = self.summary()
        wall = f"{s['wall_s']:.2f}s" if s["wall_s"] is not None else "n/a"
        lines = [
            f"served {s['completed']} requests in {s['batches']} batches "
            f"({s['rejected']} rejected, {s['padded']} padded lanes)",
            f"  latency p50={s['p50_ms']:.1f}ms p99={s['p99_ms']:.1f}ms  "
            f"throughput={s['fps']:.1f} fps  wall={wall}",
            f"  executable cache: {s['cache_hits']} hits / "
            f"{s['cache_misses']} misses",
        ]
        for b in sorted(self.buckets.values(), key=lambda b: -b.requests):
            d = b.to_dict()
            lines.append(
                f"  bucket {d['signature'][:72]}: {d['requests']} reqs / "
                f"{d['batches']} batches (mean {d['mean_batch']:.1f}), "
                f"p99={d['p99_ms']:.1f}ms"
            )
        return "\n".join(lines)
