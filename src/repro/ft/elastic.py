"""Elastic scaling: replan the mesh after node loss/gain and reshard.

Policy: the 'model' axis extent is a correctness-critical divisor of head /
ffn / expert dims, so elasticity happens on the DATA (and pod) axes — we keep
the model axis fixed and shrink/grow data parallelism to the largest
supported size that fits the surviving hosts, then restore from the latest
checkpoint with the new shardings (CheckpointManager.restore(sharding_tree)).
The deterministic counter-based data stream makes the resume exact: every
(step, row) is recomputable on whichever host now owns it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    dropped_batch_rows: int        # global-batch rows re-balanced per step
    note: str


def plan_elastic_mesh(
    available_devices: int,
    model_parallel: int,
    global_batch: int,
    prefer_pods: bool = True,
    devices_per_pod: int = 256,
) -> Optional[ElasticPlan]:
    """Largest (pod, data, model) mesh with the fixed model axis that fits.

    Returns None when fewer than one model-parallel group survives (training
    cannot continue; caller should hold at the last checkpoint and page ops).
    """
    if available_devices < model_parallel:
        return None
    groups = available_devices // model_parallel  # data-parallel replicas
    # keep batch divisible: largest data size dividing global_batch
    data = groups
    while data > 1 and global_batch % data:
        data -= 1
    pods = 1
    if prefer_pods and devices_per_pod % model_parallel == 0:
        per_pod_groups = devices_per_pod // model_parallel
        if data >= per_pod_groups and data % per_pod_groups == 0:
            pods = data // per_pod_groups
            data = per_pod_groups
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    if pods > 1:
        shape, axes = (pods, data, model_parallel), ("pod", "data", "model")
    else:
        shape, axes = (data, model_parallel), ("data", "model")
    used = pods * data * model_parallel
    return ElasticPlan(
        mesh_shape=shape,
        mesh_axes=axes,
        dropped_batch_rows=0,
        note=(
            f"{available_devices} devices -> mesh {shape} "
            f"({available_devices - used} idle)"
        ),
    )
