from repro.ft.heartbeat import HeartbeatMonitor, StragglerReport
from repro.ft.elastic import ElasticPlan, plan_elastic_mesh

__all__ = [
    "HeartbeatMonitor",
    "StragglerReport",
    "ElasticPlan",
    "plan_elastic_mesh",
]
