"""Straggler detection via per-host step heartbeats.

At scale every host reports (host_id, step, wall_time) after each step; the
monitor flags hosts whose step latency exceeds a robust threshold
(median + k * IQR over a sliding window) — the standard straggler-mitigation
trigger (re-scheduling, checkpoint-evict, or slice replacement upstream).
Pure-python and unit-testable; at deployment the transport is the cluster's
control plane, not this class's concern.
"""
from __future__ import annotations

import dataclasses
import statistics
from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StragglerReport:
    step: int
    stragglers: List[int]          # host ids
    slow_factor: Dict[int, float]  # host -> latency / median
    median_s: float


class HeartbeatMonitor:
    def __init__(
        self,
        n_hosts: int,
        window: int = 16,
        iqr_k: float = 3.0,
        min_factor: float = 1.5,
        miss_timeout_s: float = 60.0,
    ):
        self.n_hosts = n_hosts
        self.window = window
        self.iqr_k = iqr_k
        self.min_factor = min_factor
        self.miss_timeout_s = miss_timeout_s
        self._lat: Dict[int, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window)
        )
        self._last_seen: Dict[int, float] = {}

    def report(self, host: int, step: int, latency_s: float, now_s: float):
        self._lat[host].append(latency_s)
        self._last_seen[host] = now_s

    def dead_hosts(self, now_s: float) -> List[int]:
        """Hosts that stopped heartbeating entirely (node failure)."""
        return [
            h
            for h in range(self.n_hosts)
            if now_s - self._last_seen.get(h, -1e18) > self.miss_timeout_s
        ]

    def check(self, step: int) -> Optional[StragglerReport]:
        latest = {
            h: d[-1] for h, d in self._lat.items() if len(d) > 0
        }
        if len(latest) < max(2, self.n_hosts // 2):
            return None
        vals = sorted(latest.values())
        med = statistics.median(vals)
        q1 = vals[len(vals) // 4]
        q3 = vals[(3 * len(vals)) // 4]
        iqr = max(q3 - q1, 1e-9)
        thresh = max(med + self.iqr_k * iqr, med * self.min_factor)
        stragglers = sorted(h for h, v in latest.items() if v > thresh)
        if not stragglers:
            return None
        return StragglerReport(
            step=step,
            stragglers=stragglers,
            slow_factor={h: latest[h] / med for h in stragglers},
            median_s=med,
        )
