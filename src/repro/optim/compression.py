"""Gradient compression for the cross-pod (DCI) all-reduce.

Two schemes, both with the standard convergence safeguards:

  * top-k sparsification with ERROR FEEDBACK (Stich et al.): each worker
    keeps the residual of what it did not transmit and adds it to the next
    step's gradient — unbiased in the limit, required for convergence.
  * int8 quantization with per-chunk scales and STOCHASTIC ROUNDING.

At deployment these wrap the pod-axis psum only (the intra-pod ICI reduce
stays fp32 — it is fast); the API therefore compresses/decompresses around a
caller-supplied reduce function.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TopKState(NamedTuple):
    residual: jnp.ndarray  # error-feedback memory, same shape as grad


def topk_compress(
    grad: jnp.ndarray,
    state: TopKState,
    k_frac: float = 0.01,
) -> Tuple[jnp.ndarray, jnp.ndarray, TopKState]:
    """Returns (values (k,), indices (k,), new_state). Transmits only top-k
    |grad + residual| entries; the rest accumulates in the residual."""
    flat = (grad + state.residual).reshape(-1)
    k = max(1, int(flat.size * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    sparse = jnp.zeros_like(flat).at[idx].set(sel)
    new_state = TopKState(residual=(flat - sparse).reshape(grad.shape))
    return sel, idx, new_state


def topk_decompress(values, indices, shape) -> jnp.ndarray:
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), values.dtype)
    return flat.at[indices].set(values).reshape(shape)


def int8_quantize(
    x: jnp.ndarray, key: jax.Array, chunk: int = 256
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-chunk absmax int8 with stochastic rounding.
    Returns (q (N,) int8, scales (N/chunk,) f32)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = blocks / scale
    noise = jax.random.uniform(key, y.shape) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def int8_dequantize(q: jnp.ndarray, scales: jnp.ndarray, shape, chunk: int = 256):
    blocks = q.reshape(-1, chunk).astype(jnp.float32) * scales[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_psum(
    grads,
    axis_name: str,
    key: jax.Array,
    scheme: str = "int8",
):
    """Drop-in psum replacement for use inside shard_map: quantize, sum the
    dequantized payloads (associativity-safe), return mean-preserving result.
    """
    def one(g, k):
        if scheme == "int8":
            q, s = int8_quantize(g, k)
            deq = int8_dequantize(q, s, g.shape)
            return jax.lax.psum(deq, axis_name)
        return jax.lax.psum(g, axis_name)

    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten([one(g, k) for g, k in zip(leaves, keys)])
