"""Adafactor (Shazeer & Stern, arXiv:1804.04235) — factored second moment.

Used for the >=100B assigned architectures (kimi-k2-1t, qwen1.5-110b,
jamba-1.5-large, llava-next-34b training configs) so optimizer state fits v5e
HBM: factored rows+cols of the second moment cost O(n+m) instead of O(nm),
and no first moment by default (beta1=None) — ~0.02 bytes/param amortized vs
8 for Adam.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class FactoredLeaf(NamedTuple):
    vr: Any   # row statistics   (shape[:-1])
    vc: Any   # col statistics   (shape[:-2] + shape[-1:])
    v: Any    # full statistics for <2D leaves (None-size placeholder)


class AdafactorState(NamedTuple):
    stats: Any  # pytree of FactoredLeaf


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params) -> AdafactorState:
    def init_leaf(p):
        if _factored(p.shape):
            return FactoredLeaf(
                vr=jnp.zeros(p.shape[:-1], jnp.float32),
                vc=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                v=jnp.zeros((1,), jnp.float32),
            )
        return FactoredLeaf(
            vr=jnp.zeros((1,), jnp.float32),
            vc=jnp.zeros((1,), jnp.float32),
            v=jnp.zeros(p.shape, jnp.float32),
        )

    return AdafactorState(
        stats=jax.tree.map(init_leaf, params),
    )


def adafactor_update(
    params,
    grads,
    state: AdafactorState,
    step,
    lr=1e-2,
    decay: float = 0.8,
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
):
    if callable(lr):
        lr = lr(step)
    lr = jnp.asarray(lr, jnp.float32)
    t = jnp.asarray(step, jnp.float32) + 1.0
    beta2 = 1.0 - jnp.power(t, -decay)

    def upd(p, g, s: FactoredLeaf):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps1
        if _factored(p.shape):
            vr = beta2 * s.vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * s.vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            # rank-1 reconstruction of the second moment
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps1)
            vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            u = g / jnp.sqrt(vhat + eps1)
            new_s = FactoredLeaf(vr=vr, vc=vc, v=s.v)
        else:
            v = beta2 * s.v + (1 - beta2) * g2
            u = g / jnp.sqrt(v + eps1)
            new_s = FactoredLeaf(vr=s.vr, vc=s.vc, v=v)
        # update clipping (RMS)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        scale = jnp.maximum(eps2, jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))))
        new_p = p.astype(jnp.float32) - lr * scale * u - lr * weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), new_s

    def upd_leaf(p, g, s: FactoredLeaf):
        # Stacked-layer leaves (U, ...) update one unit slice at a time
        # (lax.map): the f32 temporaries (p32, g^2, vhat, u) then cost 1/U of
        # the leaf instead of several full-leaf f32 copies live at once.
        # Per-slice semantics are also the *correct* Adafactor semantics:
        # each unit slice is one layer's tensor.
        if p.ndim >= 3 and p.shape[0] > 1 and _factored(p.shape[1:]):
            def one(args):
                pi, gi, vri, vci = args
                new_p, new_s = upd(pi, gi, FactoredLeaf(vr=vri, vc=vci, v=s.v))
                return new_p, new_s.vr, new_s.vc

            if p.shape[0] <= 4:  # small stacks: unroll (exact cost analysis)
                outs = [one((p[i], g[i], s.vr[i], s.vc[i]))
                        for i in range(p.shape[0])]
                new_p = jnp.stack([o[0] for o in outs])
                vr = jnp.stack([o[1] for o in outs])
                vc = jnp.stack([o[2] for o in outs])
            else:
                new_p, vr, vc = jax.lax.map(one, (p, g, s.vr, s.vc))
            return new_p, FactoredLeaf(vr=vr, vc=vc, v=s.v)
        return upd(p, g, s)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state.stats)
    out = [upd_leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_stats = treedef.unflatten([o[1] for o in out])
    return new_params, AdafactorState(stats=new_stats)
