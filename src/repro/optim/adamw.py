"""AdamW, hand-rolled on pytrees (no optax offline).

Supports a per-leaf learning-rate pytree (prefix-broadcast like jax.tree.map)
— used by 3D-GS scene training where each parameter group has its own lr —
or a scalar/callable lr for LM training.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    step,
    lr: Union[float, Any, Callable] = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """One AdamW step. ``lr`` may be a scalar, a schedule fn of step, or a
    pytree matching (a prefix of) params."""
    if callable(lr):
        lr = lr(step)
    t = (jnp.asarray(step, jnp.float32) + 1.0)
    c1 = 1.0 - jnp.power(b1, t)
    c2 = 1.0 - jnp.power(b2, t)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu,
        grads,
    )

    is_tree_lr = not jnp.isscalar(lr) and not isinstance(lr, (float, int, jnp.ndarray))
    if is_tree_lr:
        new_params = jax.tree.map(
            lambda p, m, v, l: (
                p
                - l * ((m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p)
            ).astype(p.dtype),
            params,
            mu,
            nu,
            lr,
        )
    else:
        lr = jnp.asarray(lr, jnp.float32)
        new_params = jax.tree.map(
            lambda p, m, v: (
                p
                - lr * ((m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p)
            ).astype(p.dtype),
            params,
            mu,
            nu,
        )
    return new_params, AdamWState(mu=mu, nu=nu)
