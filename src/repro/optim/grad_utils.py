"""Gradient utilities: global-norm clipping, finite checks."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    """sqrt(sum of squares) with f32 ACCUMULATION but no f32 materialization:
    a dot product with preferred_element_type contracts bf16 leaves into an
    f32 scalar without ever allocating a converted copy of the leaf."""
    leaves = jax.tree.leaves(tree)
    total = jnp.zeros((), jnp.float32)
    for x in leaves:
        # einsum over the ORIGINAL axes (no reshape: flattening a sharded
        # leaf would all-gather it); contraction accumulates in f32 and the
        # scalar result reduces with partial sums per shard.
        sub = "".join(chr(97 + i) for i in range(x.ndim))
        total = total + jnp.einsum(
            f"{sub},{sub}->", x, x, preferred_element_type=jnp.float32
        )
    return jnp.sqrt(total)


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    # Cast the scale to each leaf's dtype BEFORE multiplying: bf16 * f32
    # promotes the whole leaf to f32 (2x gradient memory at 100B scale).
    return (
        jax.tree.map(lambda x: x * scale.astype(x.dtype), tree),
        norm,
    )


def all_finite(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.all(
        jnp.stack([jnp.all(jnp.isfinite(x)) for x in leaves])
    )
